"""Paper Fig. 3: stability-region heat map — LHS of Eq. (3) over (M, λ).

Reproduces the paper's trade-off: ~tens of models at slow observation rates
vs a single model at ~20 obs/s, with the boundary moving from
model-count-limited to compute-limited as λ grows.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.meanfield import solve_fixed_point_batch

from benchmarks.common import emit


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    Ms = [1, 2, 3, 4, 5, 6, 8, 12, 16] if not quick else [1, 2, 4, 8]
    lams = np.geomspace(1e-3, 60.0, 7 if quick else 13)
    # the whole (M x lambda) heat map is one vmapped solve (M is purely
    # arithmetic in the mean-field path)
    grid = [(M, float(lam)) for M in Ms for lam in lams]
    sols = solve_fixed_point_batch(
        [paper_params(lam=lam, M=M) for M, lam in grid], cm
    )
    lhss = np.asarray(sols.stability)
    stables = np.asarray(sols.stable)
    rows = []
    for (M, lam), lhs, stable in zip(grid, lhss, stables):
        rows.append(dict(
            M=M, lam=round(lam, 4),
            stability_lhs=round(float(lhs), 4) if np.isfinite(lhs) else 1e9,
            stable=bool(stable),
        ))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    # derived: max stable M at the slowest rate; max stable lam at M=1
    m_max = max((r["M"] for r in rows if r["stable"]), default=0)
    lam_max = max((r["lam"] for r in rows if r["stable"] and r["M"] == 1),
                  default=0.0)
    emit("fig3_stability", rows, t0, f"Mmax={m_max};lam_max_M1={lam_max}")


if __name__ == "__main__":
    main()
