"""Gossip-vs-allreduce trainer microbenchmark (host CPU, 8 fake devices).

Measures wall time per step and final loss for a tiny transformer trained
with (a) synchronous all-reduce DP, (b) Floating Gossip with mean-field
gates — the datacenter analogue of the paper's centralized-vs-FG comparison.
Runs in a subprocess so the 8-device override never leaks into the caller.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

from benchmarks.common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time
sys.path.insert(0, %r)
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.base import ArchConfig, LayerSpec
from repro.core.gossip import GossipConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.transformer import init_lm, abstract_lm
from repro.optim import adamw
from repro.sharding.logical import DEFAULT_RULES, Lx, tree_specs
from repro.train.trainer import make_allreduce_step, make_gossip_step, train_shardings
from repro.launch.mesh import compat_make_mesh, use_mesh

mesh = compat_make_mesh((8, 1), ("data", "model"))
cfg = ArchConfig(name="bench-tiny", n_layers=2, d_model=128, n_heads=4,
                 n_kv_heads=2, d_ff=256, vocab_size=512, vocab_pad_multiple=128,
                 dtype="float32", pattern=(LayerSpec(),), remat=False)
data = SyntheticLM(DataConfig(vocab_size=512, seq_len=64, global_batch=32, seed=0))
opt = adamw(3e-3)
key = jax.random.PRNGKey(0)
out = {}

with use_mesh(mesh):
    # ---- all-reduce baseline ----
    params, _ = init_lm(cfg, key)
    state = opt.init(params)
    step_fn = jax.jit(make_allreduce_step(cfg, opt, has_encoder=False))
    losses = []
    t0 = time.time()
    for s in range(40):
        tok, lab = data.global_arrays(s, mesh)
        params, state, m = step_fn(params, state, dict(tokens=tok, labels=lab),
                                   jnp.asarray(s))
        losses.append(float(m["loss"]))
    out["allreduce"] = dict(t=time.time() - t0, loss0=losses[0], lossN=losses[-1])

    # ---- Floating Gossip ----
    abstract, pspecs, opt_abs, ospecs, _ = train_shardings(
        cfg, mesh, mode="gossip", optimizer=opt)
    R = 8
    def rep_init(k):
        ps = [init_lm(cfg, kk)[0] for kk in jax.random.split(k, R)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    params = jax.device_put(rep_init(key),
                            jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
    default = jax.tree.map(jnp.zeros_like, params)
    state = jax.vmap(opt.init)(params)
    gstate = dict(count=jnp.zeros((R,)), age=jnp.zeros((R,)))
    gcfg = GossipConfig(axis_names=("data",), matching="random",
                        success_prob=0.95, busy_prob=0.02, churn_prob=0.0,
                        merge_policy="obs_count")
    gstep, _ = make_gossip_step(cfg, opt, mesh, pspecs, gcfg, has_encoder=False)
    gstep = jax.jit(gstep)
    losses = []
    t0 = time.time()
    for s in range(40):
        tok, lab = data.global_arrays(s, mesh)
        batch = dict(tokens=tok.reshape(R, 4, 64), labels=lab.reshape(R, 4, 64))
        params, state, gstate, m = gstep(params, state, gstate, default,
                                         batch, jnp.asarray(s))
        losses.append(float(m["loss"]))
    out["gossip"] = dict(t=time.time() - t0, loss0=losses[0], lossN=losses[-1])

print(json.dumps(out))
"""


def run(quick: bool = False) -> list[dict]:
    code = _BODY % os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    res = json.loads(r.stdout.strip().splitlines()[-1])
    rows = []
    for mode, d in res.items():
        rows.append(dict(mode=mode, wall_s=round(d["t"], 2),
                         loss_first=round(d["loss0"], 3),
                         loss_last=round(d["lossN"], 3)))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    g = next(r for r in rows if r["mode"] == "gossip")
    a = next(r for r in rows if r["mode"] == "allreduce")
    emit("gossip_throughput", rows, t0,
         f"gossip_final={g['loss_last']};allreduce_final={a['loss_last']}")


if __name__ == "__main__":
    main()
