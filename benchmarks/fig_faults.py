"""Fault-layer validation: simulation vs the class-structured mean field.

The fault-injection layer (``repro.sim.faults``) breaks the paper's
homogeneity assumptions — duty-cycled radios, mid-transfer link
failures, setup aborts, crash-restart churn — and the class-structured
solver (``meanfield.solve_fixed_point_classes``) extends Lemmas 1-3 to a
(class × zone) coupled balance that claims to predict the per-class
availability anyway. This figure is that claim, tested: a 2-class
population (always-on + duty-cycled) swept over duty cycle and link
failure rate, comparing the simulator's per-class availability telemetry
(``availability_c``) against the analytic fixed point.

Rows: one per (duty, link failure) point with the per-class sim /
mean-field availabilities and relative errors, the measured accessible
fraction of the duty class against its stationary duty (the tightest
check — it isolates the on/off chain from gossip dynamics), and the
cumulative fault event counters. Derived: the worst per-class relative
error, which must stay within the 15% acceptance tolerance.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.fg_faults import duty_mix
from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.meanfield import solve_fixed_point_classes
from repro.sim import SimConfig, sweep

from benchmarks.common import emit, rel_err

LAM = 0.05        # the fig-1 default operating point
TOL = 0.15        # ISSUE acceptance: sim vs class solver within 15%


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    p = paper_params(lam=LAM, M=1)
    if quick:
        points = [(0.5, 0.0), (0.8, 0.02)]
        n_slots, seeds = 4000, 2
    else:
        points = [(0.3, 0.0), (0.5, 0.0), (0.7, 0.0), (0.9, 0.0),
                  (0.5, 0.02), (0.8, 0.02), (0.8, 0.05)]
        n_slots, seeds = 8000, 4

    rows = []
    for duty, link_rate in points:
        fc = duty_mix(duty=duty, frac_duty=0.5, link_fail_rate=link_rate)
        cfg = SimConfig(n_slots=n_slots, sample_every=8, faults=fc)
        csol = solve_fixed_point_classes(p, cm, faults=fc)
        a_model = np.asarray(csol.a)[:, 0]                # (C,)

        t0 = time.time()
        summ = sweep.run([p], cfg, seeds=range(seeds), reduce="mean",
                         warmup_frac=0.5)
        wall = time.time() - t0
        # stats["availability_c"]: (scen, seed, M, C) time-means
        a_sim = np.asarray(summ.stats["availability_c"])[0, :, 0, :]
        a_sim = a_sim.mean(axis=0)                        # (C,)
        on_sim = np.asarray(summ.stats["on_frac_c"])[0].mean(axis=0)
        ev = np.asarray(summ.stats["fault_events"])[0].sum(axis=0)
        q_duty = fc.classes[1].duty

        rows.append(dict(
            duty=duty,
            link_fail_rate=link_rate,
            a_model_on=round(float(a_model[0]), 4),
            a_sim_on=round(float(a_sim[0]), 4),
            err_on=round(rel_err(float(a_model[0]), float(a_sim[0])), 4),
            a_model_duty=round(float(a_model[1]), 4),
            a_sim_duty=round(float(a_sim[1]), 4),
            err_duty=round(
                rel_err(float(a_model[1]), float(a_sim[1])), 4),
            on_frac_duty=round(float(on_sim[1]), 4),
            err_on_frac=round(rel_err(q_duty, float(on_sim[1])), 4),
            linkfail_events=int(ev[1]),
            wall_s=round(wall, 1),
        ))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    errs = np.asarray(
        [[r["err_on"], r["err_duty"]] for r in rows], float)
    on_errs = np.asarray([r["err_on_frac"] for r in rows], float)
    worst = float(errs.max())
    emit("fig_faults", rows, t0,
         f"worst_class_err={worst:.3f} tol_ok={worst <= TOL} "
         f"worst_on_frac_err={float(on_errs.max()):.3f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
