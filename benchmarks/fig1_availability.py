"""Paper Fig. 1: mean model availability `a` and node stored information vs
model size L — mean-field model vs the Monte-Carlo simulator.

Reproduces the paper's validation claim: the mean-field estimates match the
simulation across parameter settings, with the mean-field being slightly
optimistic near the contact-capacity limit (finite-size effect).

The whole (variant x L) grid runs as ONE sweep on the fleet runner
(``repro.sim.sweep``) with the post-warmup time-means reduced *on
device* — the per-slot traces this figure aggregates never cross the
device/host boundary — plus one vmapped mean-field solve, instead of the
old serial per-point loop.
"""

from __future__ import annotations

import time

from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.capacity import node_stored_information
from repro.core.dde import solve_observation_availability
from repro.core.meanfield import solve_fixed_point_batch
from repro.sim import SimConfig, sweep

from benchmarks.common import emit, rel_err


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    Ls = [10e3, 100e3] if quick else [10e3, 50e3, 100e3, 500e3]
    variants = [("TT5_TM2.5", 5.0, 2.5)] if quick else [
        ("TT5_TM2.5", 5.0, 2.5), ("TT0.5_TM0.25", 0.5, 0.25),
    ]
    n_slots = 4000 if quick else 12000

    grid = [(tag, T_T, T_M, L) for tag, T_T, T_M in variants for L in Ls]
    ps = [paper_params(lam=0.05, M=1, T_T=T_T, T_M=T_M, L=L)
          for _, T_T, T_M, L in grid]

    sols = solve_fixed_point_batch(ps, cm)
    summ = sweep.run(ps, SimConfig(n_slots=n_slots, sample_every=32),
                     seeds=[1], reduce="mean", warmup_frac=0.5)

    rows = []
    for i, ((tag, T_T, T_M, L), p) in enumerate(zip(grid, ps)):
        # per-point DDE on the batched operating point
        sol = sols.point(i)
        dde = solve_observation_availability(p, sol)
        stored_mf = float(node_stored_information(p, sol, dde.integral(p.tau_l)))
        a_sim = float(summ.stats["availability"][i, 0].mean())
        stored_sim = float(summ.stats["stored"][i, 0])
        a_mf = float(sols.a[i])
        rows.append(dict(
            variant=tag, L=L,
            a_meanfield=round(a_mf, 4), a_sim=round(a_sim, 4),
            a_rel_err=round(rel_err(a_mf, a_sim), 3),
            stored_meanfield=round(stored_mf, 2),
            stored_sim=round(stored_sim, 2),
            stored_rel_err=round(rel_err(stored_mf, stored_sim), 3),
            busy_meanfield=round(float(sols.b[i]), 4),
            busy_sim=round(float(summ.stats["busy_frac"][i, 0]), 4),
        ))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    worst = max(r["a_rel_err"] for r in rows)
    emit("fig1_availability", rows, t0, f"worst_a_rel_err={worst}")


if __name__ == "__main__":
    main()
