"""Paper Fig. 4: normalized model staleness F·λ vs observation rate λ, for
several model counts M.

Reproduces the claims: (i) normalized staleness rises then falls with λ and
curves stop at instability; (ii) staleness grows sub-linearly in M
(paper: M=1 -> 25 costs only ~10% at the peak).
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.dde import solve_observation_availability_batch
from repro.core.meanfield import solve_fixed_point_batch
from repro.core.staleness import staleness_lower_bound_batch
from repro.sim import SimConfig, sweep

from benchmarks.common import emit, rel_err


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    Ms = [1, 4] if quick else [1, 5, 25]
    lams = np.geomspace(0.01, 2.0, 6 if quick else 10)
    grid = [(M, float(lam)) for M in Ms for lam in lams]
    ps = [paper_params(lam=lam, M=M) for M, lam in grid]
    # mean-field + DDE + Theorem-2 bound over the (M x lambda) grid as
    # batched programs — no Python loop over grid points
    sols = solve_fixed_point_batch(ps, cm)
    dde = solve_observation_availability_batch(ps, sols, dt=0.1)
    F = np.asarray(staleness_lower_bound_batch(ps, dde))
    stable = np.asarray(sols.stable)
    rows = [
        dict(
            M=M, lam=round(lam, 4),
            staleness_s=round(float(F[i]), 2),
            normalized=round(float(F[i]) * lam, 3),
            a_sim_rel_err=None,
        )
        for i, (M, lam) in enumerate(grid) if stable[i]
    ]
    # Monte-Carlo spot-check of a stable M=1 operating point near the
    # paper's λ range on the sweep runner's reduced-output path: the
    # mean-field availability the staleness bound builds on must track
    # the simulator. (Very small λ is excluded — availability is then ~0
    # and the relative error degenerates.)
    cand = [i for i, (M, lam) in enumerate(grid)
            if M == 1 and stable[i] and lam >= 0.04]
    check = min(cand, key=lambda i: abs(grid[i][1] - 0.07), default=None)
    if check is not None:
        summ = sweep.run(
            [ps[check]], SimConfig(n_slots=4000 if quick else 8000,
                                   sample_every=32),
            seeds=[0, 1], reduce="mean", warmup_frac=0.5,
        )
        a_sim = float(summ.stats["availability"][0].mean())
        a_mf = float(np.asarray(sols.a)[check])
        rows.append(dict(
            M=1, lam=round(grid[check][1], 4), staleness_s=None,
            normalized=None,
            a_sim_rel_err=round(rel_err(a_mf, a_sim), 3),
        ))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    peak = {m: max((r["normalized"] for r in rows
                    if r["M"] == m and r["normalized"] is not None), default=0)
            for m in {r["M"] for r in rows}}
    ms = sorted(peak)
    growth = peak[ms[-1]] / max(peak[ms[0]], 1e-9) if len(ms) > 1 else 1.0
    emit("fig4_staleness", rows, t0, f"peak_growth_M{ms[0]}to{ms[-1]}={growth:.2f}")


if __name__ == "__main__":
    main()
