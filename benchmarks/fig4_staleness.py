"""Paper Fig. 4: normalized model staleness F·λ vs observation rate λ, for
several model counts M.

Reproduces the claims: (i) normalized staleness rises then falls with λ and
curves stop at instability; (ii) staleness grows sub-linearly in M
(paper: M=1 -> 25 costs only ~10% at the peak).
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.dde import solve_observation_availability_batch
from repro.core.meanfield import solve_fixed_point_batch
from repro.core.staleness import staleness_lower_bound_batch

from benchmarks.common import emit


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    Ms = [1, 4] if quick else [1, 5, 25]
    lams = np.geomspace(0.01, 2.0, 6 if quick else 10)
    grid = [(M, float(lam)) for M in Ms for lam in lams]
    ps = [paper_params(lam=lam, M=M) for M, lam in grid]
    # mean-field + DDE + Theorem-2 bound over the (M x lambda) grid as
    # batched programs — no Python loop over grid points
    sols = solve_fixed_point_batch(ps, cm)
    dde = solve_observation_availability_batch(ps, sols, dt=0.1)
    F = np.asarray(staleness_lower_bound_batch(ps, dde))
    stable = np.asarray(sols.stable)
    return [
        dict(
            M=M, lam=round(lam, 4),
            staleness_s=round(float(F[i]), 2),
            normalized=round(float(F[i]) * lam, 3),
        )
        for i, (M, lam) in enumerate(grid) if stable[i]
    ]


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    peak = {m: max((r["normalized"] for r in rows if r["M"] == m), default=0)
            for m in {r["M"] for r in rows}}
    ms = sorted(peak)
    growth = peak[ms[-1]] / max(peak[ms[0]], 1e-9) if len(ms) > 1 else 1.0
    emit("fig4_staleness", rows, t0, f"peak_growth_M{ms[0]}to{ms[-1]}={growth:.2f}")


if __name__ == "__main__":
    main()
