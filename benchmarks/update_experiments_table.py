"""Regenerate the §Roofline table inside EXPERIMENTS.md from reports/dryrun.

Replaces the markdown table between the '| arch |' header and the blank
line before 'Reading of the table'. Idempotent.
"""

import glob
import json
import re

HEADER = ("| arch | shape | mode | compute ms | memory ms | collective ms "
          "| dominant | useful | temp GB/dev |")


def build_table() -> str:
    rows = []
    for path in sorted(glob.glob("reports/dryrun/single_*.json")):
        r = json.load(open(path))
        rf = r["roofline"]
        rows.append((r["arch"], r["shape"], r["mode"],
                     rf["compute_s"] * 1e3, rf["memory_s"] * 1e3,
                     rf["collective_s"] * 1e3,
                     rf["dominant"].replace("_s", ""),
                     r["useful_flops_ratio"],
                     (r["bytes_per_device"] or 0) / 1e9))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda x: (x[0], order[x[1]]))
    lines = [HEADER, "|---|---|---|---|---|---|---|---|---|"]
    for a, s, m, c, me, co, d, u, t in rows:
        lines.append(f"| {a} | {s} | {m} | {c:.2f} | {me:.2f} | {co:.2f} "
                     f"| **{d}** | {u:.2f} | {t:.2f} |")
    return "\n".join(lines)


def main():
    txt = open("EXPERIMENTS.md").read()
    table = build_table()
    pat = re.compile(
        r"\| arch \| shape \| mode \|.*?(?=\n\nReading of the table)",
        re.DOTALL,
    )
    new, n = pat.subn(table, txt)
    assert n == 1, f"table anchor not found ({n})"
    open("EXPERIMENTS.md", "w").write(new)
    print(f"updated table ({table.count(chr(10)) - 1} rows)")


if __name__ == "__main__":
    main()
