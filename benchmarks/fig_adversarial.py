"""Byzantine robustness: attacks, defended merges, contamination twin.

The Byzantine layer (adversarial classes in ``repro.sim.faults`` +
``repro.core.merge.DefenseConfig``) claims three things this figure
tests end to end on the learning-smoke operating point:

1. **Undefended collapse** — holder accuracy degrades monotonically as
   the sign-flip attacker fraction grows (amplified sign-flip, the
   workhorse attack of ``repro.configs.fg_adversarial``);
2. **Defended recovery** — the calibrated "clipped" defense (norm clip +
   distance gate + count clamp) restores at least 90% of the clean
   accuracy at every attack point, while the trimmed-median arm shows
   the defense-cost trade-off (median mixing is slower than averaging,
   costing a few points even under clean conditions);
3. **Contamination twin** — the compartment model
   (``meanfield.solve_contamination_classes`` + the
   ``dde.solve_contamination_transient`` lane) predicts the measured
   poisoned-replica fraction within 15%. The 240 s runs sit mid-epidemic
   at small attacker fractions, so the twin is evaluated as a
   *transient* over the simulator's own averaging window, fed with two
   measured rates: the per-node delivery rate (cumulative
   ``merge_stats`` attempts — finite-size sims run below the Lemma 2
   contact rate) and the defended acceptance probability ``eta_adv``
   (poison-attributed reject counters). What the twin then *predicts* is
   the nonlinear contagion balance — seeding by attacker share,
   epidemic self-spread through honest merges, churn cleaning — and the
   holder-conditioning map onto the holder-masked telemetry. One more
   finite-size effect needs handling: with ~2 attackers among 48 nodes
   and a defense rejecting most early poison attempts, the contagion
   branching process has a real die-out probability, and per-seed
   outcomes are bimodal (extinct seeds end near 0, ignited seeds near
   the epidemic level). A deterministic compartment model describes the
   epidemic *conditional on ignition*, so the comparison conditions the
   measured fraction (and the measured rates feeding the twin) on the
   seeds that ignited, and reports the ignition count per row.

Rows: one per (attacker fraction, defense arm) with the measured holder
accuracy, the poisoned fraction, the merge-screen counters, the measured
``eta_adv``, and the twin's prediction + relative error. Derived: the
undefended monotonicity flag, the defended-recovery ratio at the 10%
preset, and the worst twin error.
"""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.configs.fg_adversarial import (
    robust_defense, signflip, trimmed_defense,
)
from repro.configs.fg_learn import logreg_task
from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.dde import solve_contamination_transient
from repro.core.meanfield import solve_contamination_classes
from repro.sim import SimConfig, sweep
from repro.sim.learn import (
    MS_ATTEMPT_POISON, MS_DISTREJ_POISON,
)

from benchmarks.common import emit, rel_err

LAM = 0.05        # observation rate of the learning-smoke point
LAM_OBS = 10.0    # Λ: enough observation traffic to train within a run
TOL = 0.15        # ISSUE acceptance: sim vs contamination twin within 15%
RECOVER = 0.90    # defended accuracy must reach this fraction of clean
TAIL = 20         # accuracy/poisoned-frac averaging window (samples)
IGNITE = 0.1      # tail poisoned fraction above which a seed "ignited"
                  # (outcomes are bimodal: extinct seeds sit <0.05,
                  # ignited ones >0.4, so the threshold is uncritical)

# the learning-smoke geometry: dense contacts in a small arena so the
# 960-slot runs train to a stable plateau
CFG_KW = dict(n_nodes=48, area_side=100.0, rz_radius=50.0, n_slots=960,
              sample_every=8, k_obs=32)

ARMS = {
    "undefended": None,
    "clipped": robust_defense(),
    "trimmed": trimmed_defense(),
}


def smoke_params():
    """The mean-field twin of the learning-smoke geometry: the paper
    scenario re-scaled to the 48-node arena at its own density (RZ = the
    inscribed disc of radius ``area/2``, paper speed v = 1)."""
    density = CFG_KW["n_nodes"] / CFG_KW["area_side"] ** 2
    r_rz = CFG_KW["rz_radius"]
    return paper_params(lam=LAM, Lam=LAM_OBS, M=1).replace(
        N=density * math.pi * r_rz**2,
        alpha=2.0 * density * 1.0 * r_rz,
    )


def _measured_eta(ms: np.ndarray) -> float:
    """Acceptance probability of poisoned payloads from the cumulative
    merge-screen counters (seed-summed (R, 6) slice)."""
    attempts = float(ms[:, MS_ATTEMPT_POISON].sum())
    rejected = float(ms[:, MS_DISTREJ_POISON].sum())
    if attempts <= 0.0:
        return 1.0
    return max(0.0, 1.0 - rejected / attempts)


def _twin_prediction(p, cm, fc, *, eta: float, t, attempts_cum,
                     n_nodes: int) -> float:
    """The contamination twin's prediction of the tail-window
    holder-masked poisoned fraction, from measured delivery telemetry.

    ``attempts_cum`` is the seed-mean cumulative merge-attempt counter
    sampled at times ``t``. Two numbers are read off it: the merge onset
    (model spreading delays the first deliveries by ~30 s — the twin's
    clock starts there) and the steady per-node delivery rate (slope of
    the second half). The transient then runs from a clean start and is
    averaged over the same tail window the simulator reports,
    holder-conditioned."""
    att = np.asarray(attempts_cum, float)
    t = np.asarray(t, float)
    onset_i = int(np.argmax(att > 0.0))
    t_onset = float(t[onset_i]) if att[-1] > 0.0 else 0.0
    half = len(t) // 2
    dt_meas = float(t[-1] - t[half])
    m_meas = float(att[-1] - att[half]) / max(n_nodes * dt_meas, 1e-9)

    contam = solve_contamination_classes(
        p, cm, fc, eta_adv=eta, merge_rate=m_meas)
    horizon = float(t[-1]) - t_onset
    tr = solve_contamination_transient(contam, dt=0.5, t_max=horizon)
    # population trace on the twin clock, holder-conditioned, averaged
    # over the sim's tail window (mapped by the onset shift)
    xh = np.asarray(contam.holder_fraction(tr.o))         # (C, K, nt)
    f = np.asarray(contam.fracs)
    xh_pop = np.einsum("c,ck...->k...", f, xh)[0]          # (nt,)
    w0 = float(t[-TAIL]) - t_onset
    sel = (np.asarray(tr.tau) >= w0)
    return float(xh_pop[sel].mean())


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    p = smoke_params()
    if quick:
        fracs, seeds = [0.1], range(2)
    else:
        fracs, seeds = [0.05, 0.1, 0.2], range(3)
    lc_base = logreg_task()

    rows = []
    for arm, defense in ARMS.items():
        lc = dataclasses.replace(lc_base, defense=defense)
        for frac in [0.0] + fracs:
            fc = signflip(frac=frac) if frac > 0.0 else None
            cfg = SimConfig(learn=lc, faults=fc, **CFG_KW)
            t0 = time.time()
            out = sweep.run([p], cfg, seeds=seeds, reduce="trace")
            wall = time.time() - t0
            acc = float(np.asarray(
                out.test_acc_holders)[0, :, -TAIL:].mean())
            # trace mode ships the cumulative counters' full trajectory;
            # the final sample is the whole-run total
            ms = np.asarray(out.merge_stats)[0, :, -1]       # (R, 6)
            row = dict(arm=arm, adv_frac=frac, acc=round(acc, 4),
                       merge_attempts=int(ms[:, 0].sum()),
                       poisoned_frac=None, eta_adv=None,
                       poison_rejects=0, ignited=None, x_model=None,
                       contam_err=None, wall_s=round(wall, 1))
            if frac > 0.0:
                pf_seed = np.asarray(
                    out.poisoned_frac)[0, :, -TAIL:].mean(axis=1)
                ign = pf_seed > IGNITE
                row.update(
                    poisoned_frac=round(float(pf_seed.mean()), 4),
                    poison_rejects=int(ms[:, MS_DISTREJ_POISON].sum()),
                    ignited=f"{int(ign.sum())}/{len(pf_seed)}",
                )
                if ign.any():
                    # condition everything the twin sees — the measured
                    # fraction, eta, and the delivery telemetry — on the
                    # seeds where the epidemic ignited
                    poisoned = float(pf_seed[ign].mean())
                    ms_ign = ms[ign]
                    eta = (_measured_eta(ms_ign)
                           if defense is not None else 1.0)
                    # poisoned_frac is holder-masked telemetry, so
                    # compare the twin's holder-conditioned prediction
                    attempts_cum = np.asarray(
                        out.merge_stats)[0, ign, :, 0].mean(axis=0)
                    x_model = _twin_prediction(
                        p, cm, fc, eta=eta, t=np.asarray(out.t),
                        attempts_cum=attempts_cum,
                        n_nodes=CFG_KW["n_nodes"])
                    row.update(
                        poisoned_frac=round(poisoned, 4),
                        eta_adv=round(eta, 4),
                        x_model=round(x_model, 4),
                        contam_err=round(rel_err(x_model, poisoned), 4),
                    )
            rows.append(row)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    undef = [r for r in rows if r["arm"] == "undefended"]
    clean = undef[0]["acc"]
    attack_accs = [r["acc"] for r in undef]
    monotone = all(a >= b - 1e-9
                   for a, b in zip(attack_accs, attack_accs[1:]))
    # defended recovery at the 10% preset (quick mode's only point)
    at10 = {r["arm"]: r["acc"] for r in rows
            if r.get("adv_frac") == 0.1}
    recover = at10.get("clipped", 0.0) / max(clean, 1e-9)
    contam_errs = [r["contam_err"] for r in rows
                   if r["contam_err"] is not None]
    worst_contam = max(contam_errs) if contam_errs else 0.0
    emit("fig_adversarial", rows, t0,
         f"clean_acc={clean:.4f} recover_10pct={recover:.3f} "
         f"recover_ok={recover >= RECOVER} "
         f"undefended_monotone={monotone} "
         f"worst_contam_err={worst_contam:.3f} "
         f"contam_ok={worst_contam <= TOL}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
