"""Paper Fig. 2: learning capacity vs per-model observation rate λ.

Reproduces the paper's qualitative claims:
  * capacity grows with λ while the model capacity L/k is not binding,
  * peaks, then decreases sharply as the compute load approaches the
    stability boundary (curves stop where the system goes unstable),
  * 10x faster training/merging pushes the instability point ~10x in λ,
  * a small L/k caps the incorporated observations (capacity ~ 1/λ tail).
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.capacity import learning_capacity_batch
from repro.core.dde import solve_observation_availability_batch
from repro.core.meanfield import solve_fixed_point_batch

from benchmarks.common import emit

import jax.numpy as jnp


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    lams = np.geomspace(0.01, 400.0, 10 if quick else 20)
    variants = [
        ("base_L10k", dict(T_T=5.0, T_M=2.5, L=10e3)),
        ("fast_compute", dict(T_T=0.5, T_M=0.25, L=10e3)),
        ("small_capacity", dict(T_T=5.0, T_M=2.5, L=10e3, k=100.0)),
    ]
    # mean-field + DDE + capacity over the full (variant x lambda) grid as
    # batched programs — no Python loop over grid points
    grid = [(tag, float(lam), kw) for tag, kw in variants for lam in lams]
    ps = [paper_params(lam=lam, M=1, **kw) for _, lam, kw in grid]
    sols = solve_fixed_point_batch(ps, cm)
    dde = solve_observation_availability_batch(ps, sols, dt=0.1)
    caps = learning_capacity_batch(
        ps, sols, dde.integral(jnp.asarray([p.tau_l for p in ps]))
    )

    stable = np.asarray(sols.stable)
    caps = np.asarray(caps)
    return [
        dict(variant=tag, lam=round(lam, 4),
             capacity=round(float(caps[i]), 3) if stable[i] else 0.0,
             stable=bool(stable[i]))
        for i, (tag, lam, _) in enumerate(grid)
    ]


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    # derived check: fast-compute stays stable to larger lambda than base
    def max_stable(tag):
        ls = [r["lam"] for r in rows if r["variant"] == tag and r["stable"]]
        return max(ls) if ls else 0.0
    ratio = max_stable("fast_compute") / max(max_stable("base_L10k"), 1e-9)
    emit("fig2_capacity", rows, t0, f"stability_extension_x={ratio:.1f}")


if __name__ == "__main__":
    main()
