"""Paper Fig. 2: learning capacity vs per-model observation rate λ.

Reproduces the paper's qualitative claims:
  * capacity grows with λ while the model capacity L/k is not binding,
  * peaks, then decreases sharply as the compute load approaches the
    stability boundary (curves stop where the system goes unstable),
  * 10x faster training/merging pushes the instability point ~10x in λ,
  * a small L/k caps the incorporated observations (capacity ~ 1/λ tail).
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.fg_paper import paper_contact_model, paper_params
from repro.core.capacity import learning_capacity_batch
from repro.core.dde import solve_observation_availability_batch
from repro.core.meanfield import solve_fixed_point_batch
from repro.sim import SimConfig, sweep

from benchmarks.common import emit, rel_err

import jax.numpy as jnp


def _sim_check(ps_check, sols_a, quick: bool) -> list[dict]:
    """Monte-Carlo spot-check of the mean-field operating points feeding
    the capacity curve, on the sweep runner's reduced-output path (only
    the on-device post-warmup means ever reach the host)."""
    cfg = SimConfig(n_slots=4000 if quick else 8000, sample_every=32)
    summ = sweep.run(ps_check, cfg, seeds=[0, 1], reduce="mean",
                     warmup_frac=0.5)
    rows = []
    for i, p in enumerate(ps_check):
        a_sim = float(summ.stats["availability"][i].mean())
        rows.append(dict(
            variant="sim_check", lam=round(float(p.lam), 4),
            capacity=None, stable=True,
            a_meanfield=round(float(sols_a[i]), 4), a_sim=round(a_sim, 4),
            a_rel_err=round(rel_err(float(sols_a[i]), a_sim), 3),
        ))
    return rows


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    lams = np.geomspace(0.01, 400.0, 10 if quick else 20)
    variants = [
        ("base_L10k", dict(T_T=5.0, T_M=2.5, L=10e3)),
        ("fast_compute", dict(T_T=0.5, T_M=0.25, L=10e3)),
        ("small_capacity", dict(T_T=5.0, T_M=2.5, L=10e3, k=100.0)),
    ]
    # mean-field + DDE + capacity over the full (variant x lambda) grid as
    # batched programs — no Python loop over grid points
    grid = [(tag, float(lam), kw) for tag, kw in variants for lam in lams]
    ps = [paper_params(lam=lam, M=1, **kw) for _, lam, kw in grid]
    sols = solve_fixed_point_batch(ps, cm)
    dde = solve_observation_availability_batch(ps, sols, dt=0.1)
    caps = learning_capacity_batch(
        ps, sols, dde.integral(jnp.asarray([p.tau_l for p in ps]))
    )

    stable = np.asarray(sols.stable)
    caps = np.asarray(caps)
    rows = [
        dict(variant=tag, lam=round(lam, 4),
             capacity=round(float(caps[i]), 3) if stable[i] else 0.0,
             stable=bool(stable[i]),
             a_meanfield=None, a_sim=None, a_rel_err=None)
        for i, (tag, lam, _) in enumerate(grid)
    ]
    # validate two stable base operating points near the paper's λ range
    # against the simulator (two scenarios x two seeds, one reduced
    # sweep). Very small λ is excluded: availability is then ~0 and the
    # relative error degenerates.
    cand = [i for i, (tag, lam, _) in enumerate(grid)
            if tag == "base_L10k" and stable[i] and lam >= 0.04]
    check_idx = sorted(cand, key=lambda i: abs(grid[i][1] - 0.07))[:2]
    if check_idx:
        rows += _sim_check([ps[i] for i in check_idx],
                           [float(np.asarray(sols.a)[i]) for i in check_idx],
                           quick)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    # derived check: fast-compute stays stable to larger lambda than base
    def max_stable(tag):
        ls = [r["lam"] for r in rows if r["variant"] == tag and r["stable"]]
        return max(ls) if ls else 0.0
    ratio = max_stable("fast_compute") / max(max_stable("base_L10k"), 1e-9)
    worst = max((r["a_rel_err"] for r in rows if r["variant"] == "sim_check"),
                default=float("nan"))
    emit("fig2_capacity", rows, t0,
         f"stability_extension_x={ratio:.1f} sim_check_worst_a_err={worst}")


if __name__ == "__main__":
    main()
