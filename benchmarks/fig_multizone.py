"""Multi-zone Floating Gossip: per-zone availability vs zone count and
zone spacing (beyond the paper — its model is a single static RZ disc).

Two parameter studies on the coupled per-zone mean-field
(``solve_fixed_point_multizone``):

* **k sweep** — k equal zones on a ring inside the area: more zones
  shrink each zone's population (availability per zone drops) while the
  ring packing increases pairwise overlap (migration coupling partially
  compensates);
* **spacing sweep** — two equal zones at center distance d: the
  migration coupling decays from strong overlap to exactly zero at
  tangency (d = 2r), where the zones become independent single-RZ
  systems.

A Monte-Carlo ``sim_check`` row validates one overlapping two-zone
operating point end to end on the sweep runner's reduced path: the
per-zone on-device mean availabilities (``availability_z`` — the traces
gained a trailing zone axis) against the coupled fixed point.
"""

from __future__ import annotations

import math
import time

import numpy as np

from repro.configs.fg_paper import (DENSITY, SPEED_DEFAULT,
                                    paper_contact_model, paper_params)
from repro.core.meanfield import solve_fixed_point_multizone
from repro.core.zones import ZoneSet, single_zone
from repro.sim import SimConfig, sweep

from benchmarks.common import emit, rel_err

AREA_C = 100.0          # area center coordinate (200 m side)


def _ring_zones(k: int, radius: float = 40.0, ring: float = 50.0) -> ZoneSet:
    """k equal zones evenly spaced on a ring around the area center."""
    if k == 1:
        return single_zone((AREA_C, AREA_C), radius)
    centers = tuple(
        (AREA_C + ring * math.cos(2 * math.pi * z / k),
         AREA_C + ring * math.sin(2 * math.pi * z / k))
        for z in range(k)
    )
    return ZoneSet(centers=centers, radii=(radius,) * k)


def _pair_zones(d: float, radius: float = 50.0) -> ZoneSet:
    return ZoneSet(
        centers=((AREA_C - d / 2, AREA_C), (AREA_C + d / 2, AREA_C)),
        radii=(radius, radius),
    )


def _mz_row(variant, zs, p, cm, **extra) -> dict:
    mz = solve_fixed_point_multizone(
        p, cm, zs, density=DENSITY, speed=SPEED_DEFAULT
    )
    a = np.asarray(mz.a)
    R = np.asarray(mz.R)
    off = R - np.diag(np.diag(R))
    return dict(
        variant=variant, k=zs.k,
        a_mean=round(float(a.mean()), 4),
        a_min=round(float(a.min()), 4),
        N_zone=round(float(np.asarray(mz.N_z).mean()), 1),
        coupling=round(float(off.sum(axis=1).mean()
                             / max(np.diag(R).mean(), 1e-12)), 4),
        stable=bool(np.all(np.asarray(mz.stable))),
        a_sim=None, a_worst_err=None, **extra,
    )


def _sim_check(p, zs, quick: bool) -> dict:
    """Reduced-sweep Monte-Carlo check of the k=2 coupled fixed point."""
    mz = solve_fixed_point_multizone(
        p, paper_contact_model(), zs, density=DENSITY, speed=SPEED_DEFAULT
    )
    cfg = SimConfig(n_slots=4000 if quick else 8000, sample_every=32,
                    zones=zs)
    summ = sweep.run([p], cfg, seeds=[0, 1], reduce="mean",
                     warmup_frac=0.5)
    # (P, R, M, K) on-device post-warmup means -> per-zone seed means
    a_sim = np.asarray(summ.stats["availability_z"])[0].mean(axis=(0, 1))
    a_mf = np.asarray(mz.a)
    worst = max(rel_err(float(a_mf[z]), float(a_sim[z]))
                for z in range(zs.k))
    return dict(
        variant="sim_check", k=zs.k, a_mean=round(float(a_mf.mean()), 4),
        a_min=round(float(a_mf.min()), 4), N_zone=None, coupling=None,
        stable=True, a_sim=round(float(a_sim.mean()), 4),
        a_worst_err=round(worst, 3), spacing=None,
    )


def run(quick: bool = False) -> list[dict]:
    cm = paper_contact_model()
    p = paper_params(lam=0.05, M=1)
    rows = []
    for k in ([1, 2, 4] if quick else [1, 2, 3, 4, 6, 8]):
        rows.append(_mz_row("k_sweep", _ring_zones(k), p, cm, spacing=None))
    for d in ([70.0, 110.0] if quick else [60.0, 80.0, 90.0, 100.0, 110.0,
                                           130.0]):
        rows.append(_mz_row("spacing", _pair_zones(d), p, cm, spacing=d))
    rows.append(_sim_check(p, _pair_zones(50.0, radius=60.0), quick))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    spacing = [r for r in rows if r["variant"] == "spacing"]
    # derived checks: coupling decays monotonically with spacing and is
    # exactly zero once the discs are tangent/disjoint
    mono = all(a["coupling"] >= b["coupling"]
               for a, b in zip(spacing, spacing[1:]))
    disjoint_zero = all(r["coupling"] == 0.0 for r in spacing
                        if r["spacing"] >= 100.0)
    err = next(r["a_worst_err"] for r in rows if r["variant"] == "sim_check")
    emit("fig_multizone", rows, t0,
         f"coupling_monotone={mono} disjoint_zero={disjoint_zero} "
         f"sim_check_worst_a_err={err}")


if __name__ == "__main__":
    main()
